"""Functional PIM simulator: *executes* broadcast command streams.

The timing engine (repro.core.timing) answers "how long"; this module
answers "does the orchestration compute the right thing".  It models the
strawman machine's visible state — per-bank DRAM rows, per-ALU register
files, an open-row buffer — and executes co-aligned elementwise programs
(the §4.2.2 class) command by command:

  ACT  (subset, row)        open a row in each bank of the subset
  LD   (subset, col, reg)   reg[bank] <- open_row[bank][col]
  OP   (subset, col, reg, fn) reg[bank] <- fn(reg[bank], open_row[bank][col])
  ST   (subset, col, reg)   open_row[bank][col] <- reg[bank] (write-through)

A program must respect the machine rules (registers per ALU, one open row
per bank, SIMD width) or the simulator raises — the same constraints the
paper's orchestration discussion is about.  Tests run the vector-sum
program produced by :func:`elementwise_program` against jnp oracles.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from .hwspec import PimSpec


@dataclasses.dataclass(frozen=True)
class Cmd:
    kind: str                  # act | ld | op | st
    subset: str                # even | odd | all (act only)
    row: int = 0               # act
    col: int = 0               # ld/op/st
    reg: int = 0
    fn: Callable | None = None


class PimMachine:
    """One pseudo-channel of the strawman machine.

    Command execution is vectorized over the target bank subset: DRAM rows
    are stored as one [banks, cols, lanes] array per row index, the even/odd
    bank index arrays are precomputed, and each broadcast command becomes a
    single masked NumPy gather/op/scatter over all its banks — the same
    visible semantics as a per-bank loop (one SIMD ALU per bank pair), ~10x
    faster for the functional-sim tests and PIM baselines.  ``fn`` for OP
    commands must therefore be elementwise (it receives [n_banks, lanes]
    blocks instead of one [lanes] vector at a time).
    """

    def __init__(self, spec: PimSpec | None = None):
        self.spec = spec or PimSpec()
        sp = self.spec
        self.lanes = sp.simd_lanes
        self.banks = sp.banks_per_pch
        self.cols = sp.cols_per_row
        self.mem: dict[int, np.ndarray] = {}   # row -> [banks, cols, lanes]
        # one ALU (register file) per bank *pair*
        self.regs = np.zeros((self.banks // 2, sp.pim_regs_per_alu,
                              self.lanes), np.float32)
        self._subset_idx = {
            "even": np.arange(0, self.banks, 2),
            "odd": np.arange(1, self.banks, 2),
            "all": np.arange(self.banks),
        }
        # ACT is the only way to open rows and always targets a whole
        # subset, so "which row is open" is one scalar per subset — the
        # single source of truth, and what lets compute commands use the
        # strided-view fast path.
        self._open = {"even": -1, "odd": -1}

    @property
    def open_row(self) -> np.ndarray:
        """Per-bank open-row view (derived; ACT keeps subsets uniform)."""
        out = np.empty((self.banks,), np.int64)
        out[0::2] = self._open["even"]
        out[1::2] = self._open["odd"]
        return out

    # ------------------------------------------------------------------
    def _row_store(self, row: int) -> np.ndarray:
        return self.mem.setdefault(
            row, np.zeros((self.banks, self.cols, self.lanes), np.float32))

    def write_row(self, bank: int, row: int, data: np.ndarray) -> None:
        assert data.shape == (self.cols, self.lanes)
        self._row_store(row)[bank] = data.astype(np.float32)

    def read_row(self, bank: int, row: int) -> np.ndarray:
        return self._row_store(row)[bank]

    def _banks(self, subset: str) -> np.ndarray:
        return self._subset_idx[subset]

    # ------------------------------------------------------------------
    def execute(self, program: Sequence[Cmd]) -> None:
        nregs = self.spec.pim_regs_per_alu
        regs = self.regs
        mem = self.mem
        opened = self._open
        for cmd in program:
            kind = cmd.kind
            if kind == "act":
                subset, row = cmd.subset, cmd.row
                if subset != "odd":
                    opened["even"] = row
                if subset != "even":
                    opened["odd"] = row
                continue
            subset = cmd.subset
            if subset == "all":
                raise ValueError("compute commands target even/odd subsets")
            if not 0 <= cmd.reg < nregs:
                raise ValueError(f"register {cmd.reg} out of range")
            row = opened[subset]
            start = 0 if subset == "even" else 1
            if row < 0:
                raise RuntimeError(f"bank {start}: no open row")
            buf = mem.get(row)
            if buf is None:
                buf = self._row_store(row)
            # strided views: subset banks are buf[start::2], and bank 2a /
            # 2a+1 share ALU a, so the subset's register lane is regs[:, r]
            block = buf[start::2, cmd.col]
            if kind == "ld":
                regs[:, cmd.reg] = block
            elif kind == "op":
                regs[:, cmd.reg] = cmd.fn(regs[:, cmd.reg], block)
            elif kind == "st":
                block[...] = regs[:, cmd.reg]   # write-through
            else:
                raise ValueError(kind)


# ---------------------------------------------------------------------------
# co-aligned elementwise programs (§4.2.2)
# ---------------------------------------------------------------------------

def place_coaligned(machine: PimMachine, arrays: dict[int, np.ndarray]):
    """Place equal-length arrays co-aligned: element i of every array in
    the same (bank, col, lane); array r lives in row r.  Returns the
    number of (col-chunk) iterations a program needs."""
    n = len(next(iter(arrays.values())))
    per_bank = machine.cols * machine.lanes
    need = machine.banks * per_bank
    if n > need:
        raise ValueError(f"array larger than one row-set ({need})")
    for row, arr in arrays.items():
        pad = np.zeros(need, np.float32)
        pad[:n] = arr
        machine._row_store(row)[:] = pad.reshape(
            machine.banks, machine.cols, machine.lanes)


def gather_coaligned(machine: PimMachine, row: int, n: int) -> np.ndarray:
    return machine._row_store(row).reshape(-1)[:n].copy()


def elementwise_program(spec: PimSpec, in_rows: Sequence[int], out_row: int,
                        fn: Callable, *, arch_aware: bool = False
                        ) -> list[Cmd]:
    """Generate the §4.2.2 schedule: per register-chunk, visit each input
    row (ld/op) then the output row (st), even/odd interleaved — the same
    phase structure the timing model charges for."""
    cols = spec.cols_per_row
    chunk = max(1, spec.pim_regs_per_alu // 2)
    program: list[Cmd] = []
    for c0 in range(0, cols, chunk):
        cspan = range(c0, min(c0 + chunk, cols))
        for phase, row in enumerate(list(in_rows) + [out_row]):
            program.append(Cmd("act", "all", row=row))
            for subset_i, subset in enumerate(("even", "odd")):
                for j, col in enumerate(cspan):
                    reg = subset_i * chunk + j
                    if phase == 0:
                        program.append(Cmd("ld", subset, col=col, reg=reg))
                    elif phase < len(in_rows):
                        program.append(Cmd("op", subset, col=col, reg=reg,
                                           fn=fn))
                    else:
                        program.append(Cmd("st", subset, col=col, reg=reg))
    return program
