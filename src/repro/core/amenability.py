"""PIM-amenability-test (paper §3).

Four characteristics, each with the paper's heuristic:

  A. *Memory bandwidth limited* — low algorithmic op/byte (below the target
     architecture's roofline ridge).
  B. *Memory residency and low on-chip reuse* — ratio of physical-memory
     accesses to on-chip-structure accesses exceeds the PIM bandwidth
     multiplier (otherwise the cache/registers win).
  C. *Operand locality* — interacting operands map (or can be mapped) to the
     same bank: single-operand, commutative-reduction, or localized
     multi-operand interaction.
  D. *Aligned data parallelism* — interacting operands sit at the same
     row/column address across banks and align within the 256-bit SIMD word
     (achievable via interleave-aware allocation).

The verdict is holistic (§3.1): a weak characteristic does not necessarily
veto PIM (optimizations may recover it) and a single strong one does not
guarantee acceleration.  The report records each characteristic, the
heuristic evidence, and an overall grade used by the offload planner.
"""
from __future__ import annotations

import dataclasses
import enum

from .hwspec import GpuSpec, PimSpec


class Interaction(enum.Enum):
    """Operand-interaction classes from §3.1.3."""

    SINGLE_OPERAND = "single-operand"        # in-place updates: trivial
    REDUCTION = "commutative-reduction"      # same-bank-first: trivial
    LOCALIZED = "localized-multi-operand"    # e.g. elementwise: co-align
    INDUCIBLE = "inducible-via-mapping"      # e.g. matrix packing for GEMV
    IRREGULAR = "irregular"                  # e.g. graph neighbors


class Verdict(enum.Enum):
    AMENABLE = "amenable"
    CONDITIONAL = "conditional"   # amenable with optimizations / care
    NOT_AMENABLE = "not-amenable"


@dataclasses.dataclass(frozen=True)
class PrimitiveProfile:
    """Inputs to the test, as a programmer would characterize a primitive."""

    name: str
    ops: float                       # algorithmic operations
    mem_bytes: float                 # bytes that must come from DRAM
    onchip_bytes: float              # bytes served by caches/registers
    interaction: Interaction
    alignable: bool                  # can allocation co-align operands?
    input_dependent_locality: bool = False   # push / ss-gemm style
    notes: str = ""

    @property
    def op_byte(self) -> float:
        total = self.mem_bytes + self.onchip_bytes
        return self.ops / total if total else float("inf")

    @property
    def mem_ratio(self) -> float:
        if self.onchip_bytes == 0:
            return float("inf")
        return self.mem_bytes / self.onchip_bytes


@dataclasses.dataclass(frozen=True)
class Characteristic:
    name: str
    passed: bool
    evidence: str


@dataclasses.dataclass(frozen=True)
class AmenabilityReport:
    profile: PrimitiveProfile
    characteristics: tuple[Characteristic, ...]
    verdict: Verdict
    guidance: str

    def summary(self) -> str:
        rows = [f"PIM-amenability: {self.profile.name} -> {self.verdict.value}"]
        for c in self.characteristics:
            rows.append(f"  [{'x' if c.passed else ' '}] {c.name}: {c.evidence}")
        rows.append(f"  guidance: {self.guidance}")
        return "\n".join(rows)


def pim_bandwidth_multiplier(pim: PimSpec, gpu: GpuSpec) -> float:
    """How much more bandwidth PIM offers over the processor's view."""
    return pim.pim_peak_gbps / gpu.effective_gbps


def run_test(profile: PrimitiveProfile, pim: PimSpec | None = None,
             gpu: GpuSpec | None = None) -> AmenabilityReport:
    pim = pim or PimSpec()
    gpu = gpu or GpuSpec()
    mult = pim_bandwidth_multiplier(pim, gpu)
    # ridge point of the *baseline* machine: ops/ns over bytes/ns.  A GPU
    # stack paired with one HBM3 device: Table 1 gives 45 TFLOP16/stack.
    ridge = 45e3 / gpu.effective_gbps     # FLOP/ns / B/ns ~ 81 op/B

    a = Characteristic(
        "memory-bandwidth-limited (low op/byte)",
        profile.op_byte < ridge,
        f"op/byte={profile.op_byte:.2f} vs ridge~{ridge:.0f}",
    )
    b = Characteristic(
        "memory-resident, low on-chip reuse",
        profile.mem_ratio > mult,
        f"mem/on-chip={profile.mem_ratio:.2f} vs PIM multiplier {mult:.2f}",
    )
    c_pass = profile.interaction in (Interaction.SINGLE_OPERAND,
                                     Interaction.REDUCTION,
                                     Interaction.LOCALIZED,
                                     Interaction.INDUCIBLE)
    c = Characteristic(
        "operand locality",
        c_pass,
        f"interaction={profile.interaction.value}",
    )
    d = Characteristic(
        "aligned data parallelism",
        profile.alignable,
        "interleave-aware allocation possible" if profile.alignable
        else "irregular addressing precludes alignment",
    )
    chars = (a, b, c, d)
    n_pass = sum(ch.passed for ch in chars)

    if not a.passed:
        verdict = Verdict.NOT_AMENABLE
        guidance = ("compute-bound: PIM's bandwidth amplification cannot "
                    "help; keep on the processor")
    elif n_pass == 4 and not profile.input_dependent_locality:
        verdict = Verdict.AMENABLE
        guidance = ("offload wholesale; co-align operands at allocation and "
                    "stage open rows through pim-registers")
    elif n_pass >= 2:
        verdict = Verdict.CONDITIONAL
        hints = []
        if not b.passed:
            hints.append("reuse favors the cache: use cache-aware selective "
                         "offload (§5.1.3)")
        if profile.interaction is Interaction.INDUCIBLE:
            hints.append("induce locality via data mapping (blocked layout, "
                         "§4.2.4) and factor the mapping cost in")
        if profile.interaction is Interaction.IRREGULAR:
            hints.append("fall back to single-bank pim-commands; expect "
                         "command-bandwidth limits (§5.1.4)")
        if not d.passed:
            hints.append("broadcast commands unavailable; single-bank "
                         "orchestration only")
        if profile.input_dependent_locality:
            hints.append("locality is input-dependent: gate the offload with "
                         "a locality predictor (§5.1.3)")
        guidance = "; ".join(hints) or "offload with careful orchestration"
    else:
        verdict = Verdict.NOT_AMENABLE
        guidance = "too few PIM-amenable characteristics; keep on processor"
    return AmenabilityReport(profile=profile, characteristics=chars,
                             verdict=verdict, guidance=guidance)
