"""Hardware specifications for the Inclusive-PIM study and the TPU target.

Two families of constants live here:

1. ``PimSpec`` / ``GpuSpec`` — the commercial-PIM strawman and the GPU+HBM3
   baseline from the paper (Tables 1 and 2).  These drive the analytical
   performance models in :mod:`repro.core.timing` and
   :mod:`repro.core.gpu_model` that reproduce the paper's figures.

2. ``TpuSpec`` — the TPU v5e target used by the roofline analysis
   (:mod:`repro.roofline`) for the dry-run cells.

All times are nanoseconds, all bandwidths are bytes/ns (== GB/s), all sizes
bytes, matching Table 2 of the paper.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PimSpec:
    """Strawman commercial-PIM design (HBM-PIM-leaning), paper Table 2.

    The derived properties reproduce the paper's bandwidth story:

    * regular HBM access: one 32 B column word per ``tccds`` per pseudo
      channel -> 32 pCH * 32 B / 1.667 ns = 614.4 GB/s peak (Table 2).
    * broadcast pim-command: issued once per ``tccdl`` (half the regular
      rate, footnote 3), executed by the 8 PIM units of one even/odd bank
      subset -> 8 * 32 B / 3.333 ns = 76.8 GB/s per pCH = 2457.6 GB/s per
      stack = 4x the external peak — the paper's "about 4x" upper bound.
    """

    # --- DRAM geometry (Table 2) ---
    banks_per_pch: int = 16
    banks_per_stack: int = 512
    row_buffer_bytes: int = 1024          # per bank
    dram_word_bytes: int = 32             # one column access / SIMD word
    # --- DRAM timing (Table 2) ---
    t_rp_ns: float = 15.0                 # precharge
    t_ras_ns: float = 33.0                # min row-open time
    t_ccdl_ns: float = 10.0 / 3.0         # 3.33 ns: same-bank-group CAS gap
    t_rcd_ns: float = 15.0                # activate-to-access (not in Table 2;
                                          # standard HBM3-class value, = tRP)
    # --- PIM resources (Table 2) ---
    pim_units_per_stack: int = 256        # one ALU per bank *pair*
    pim_regs_per_alu: int = 16            # 256 b (= 32 B) each
    simd_lanes: int = 16                  # 256 b / 16 b
    # --- External interface (Table 2) ---
    peak_hbm_gbps: float = 614.4          # GB/s per stack
    # --- knobs for the §5.1.4 limit studies ---
    command_bw_mult: float = 1.0          # extra command bus capacity for
                                          # data-less single-bank commands

    # ---------------- derived ----------------
    @property
    def pch_per_stack(self) -> int:
        return self.banks_per_stack // self.banks_per_pch

    @property
    def t_ccds_ns(self) -> float:
        """Min gap between regular column commands (different bank group)."""
        return self.t_ccdl_ns / 2.0

    @property
    def banks_per_subset(self) -> int:
        """Banks driven by one broadcast pim-command (even OR odd half)."""
        return self.banks_per_pch // 2

    @property
    def cols_per_row(self) -> int:
        return self.row_buffer_bytes // self.dram_word_bytes

    @property
    def broadcast_bytes_per_cmd(self) -> int:
        """Bytes touched by one broadcast pim-command in one pCH."""
        return self.banks_per_subset * self.dram_word_bytes

    @property
    def pim_peak_gbps(self) -> float:
        """PIM data bandwidth per stack (Table 1: ~1229 GB/s for HBM-PIM at
        1.2 GHz; our strawman runs HBM3 timing so it lands at 4x ext-peak)."""
        per_pch = self.broadcast_bytes_per_cmd / self.t_ccdl_ns
        return per_pch * self.pch_per_stack

    @property
    def regular_bytes_per_ns_per_pch(self) -> float:
        return self.dram_word_bytes / self.t_ccds_ns

    @property
    def row_cycle_ns(self) -> float:
        """tRC: min time between activations of the same bank."""
        return self.t_ras_ns + self.t_rp_ns

    @property
    def row_switch_ns(self) -> float:
        """Critical-path cost of moving an open row to a new row once tRAS
        has elapsed: precharge + activate-to-data."""
        return self.t_rp_ns + self.t_rcd_ns


@dataclasses.dataclass(frozen=True)
class GpuSpec:
    """GPU + HBM3 baseline (paper §4.3.1).

    Execution time is bandwidth-only: ``bytes / (efficiency * peak)`` with
    perfect on-chip reuse except where the paper says otherwise (wavesim
    inter-timestep, push cache hit rates, ss-gemm row sparsity).
    """

    peak_hbm_gbps: float = 614.4
    bw_efficiency: float = 0.90           # "assumed to be 90% of peak"
    cache_line_bytes: int = 64
    l2_capacity_bytes: int = 4 * 1024 * 1024   # cache model: 4 MiB
    l2_ways: int = 16                          # 16-way LRU
    reduced_access_bytes: int = 32        # cache-aware GPU: 32 B accesses

    @property
    def effective_gbps(self) -> float:
        return self.peak_hbm_gbps * self.bw_efficiency


@dataclasses.dataclass(frozen=True)
class TpuSpec:
    """TPU v5e roofline constants (per chip) used by §Roofline."""

    peak_bf16_tflops: float = 197.0
    hbm_gbps: float = 819.0
    ici_link_gbps: float = 50.0           # per link
    ici_links: int = 4                    # 2D torus: 4 links/chip
    hbm_bytes: int = 16 * 1024**3
    vmem_bytes: int = 128 * 1024**2
    mxu_tile: int = 128                   # MXU systolic dim
    lane_tile: int = 128                  # last-dim register tiling
    sublane_tile: int = 8                 # fp32 second-minor tiling

    @property
    def peak_flops_per_ns(self) -> float:
        return self.peak_bf16_tflops * 1e3  # FLOP/ns

    @property
    def ridge_op_byte(self) -> float:
        """Arithmetic intensity at the compute/memory ridge point."""
        return self.peak_flops_per_ns / self.hbm_gbps


DEFAULT_PIM = PimSpec()
DEFAULT_GPU = GpuSpec()
DEFAULT_TPU = TpuSpec()
