"""PIM-offload planner: the paper's §3 methodology over compiled LM steps.

Given a dry-run artifact (per-device FLOPs, bytes, collective schedule) and
the arch config, the planner decomposes the step into the op classes the
framework knows (attention score/AV, FFN GEMMs, MoE dispatch+expert GEMMs,
embedding/LM-head, SSD scan, KV-cache streaming), runs the
PIM-amenability-test on each (op/byte vs the ridge, residency, operand
locality, alignment), and emits:

* the ops that would profit from PIM-style treatment on the strawman PIM
  system (with estimated speedups from the analytical §4.3 model), and
* the TPU-native action the framework actually takes for each (which
  Pallas kernel / schedule applies) — the §2-of-DESIGN mapping made
  operational.

This is what turns "a methodology for programmers" into a first-class
framework feature: `python -m examples.offload_planner --arch <id>`.
"""
from __future__ import annotations

import dataclasses

from .amenability import (AmenabilityReport, Interaction, PrimitiveProfile,
                          Verdict, run_test)
from .hwspec import DEFAULT_GPU, DEFAULT_PIM, DEFAULT_TPU
from ..configs.base import ArchConfig, BlockKind, ShapeConfig

ELEM = 2  # bf16


@dataclasses.dataclass(frozen=True)
class OpClass:
    name: str
    ops: float                  # flops (global, per step)
    mem_bytes: float            # unavoidable HBM traffic
    onchip_bytes: float         # traffic served by reuse if cached
    interaction: Interaction
    alignable: bool
    input_dependent: bool
    tpu_action: str             # what this framework does about it


def decompose(cfg: ArchConfig, shape: ShapeConfig) -> list[OpClass]:
    t = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    d = cfg.d_model
    ctx = shape.seq_len
    out: list[OpClass] = []
    n_attn = sum(s.count for s in cfg.resolved_segments()
                 if s.kind in (BlockKind.DENSE, BlockKind.MOE))
    n_dense = sum(s.count for s in cfg.resolved_segments()
                  if s.kind is BlockKind.DENSE)
    n_moe = sum(s.count for s in cfg.resolved_segments()
                if s.kind is BlockKind.MOE)
    n_ssm = sum(s.count for s in cfg.resolved_segments()
                if s.kind is BlockKind.SSM)

    if n_attn and cfg.attn.value != "none" and shape.kind == "decode":
        hd = (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim if cfg.mla
              else cfg.kv_heads * cfg.resolved_head_dim)
        cache_bytes = shape.global_batch * ctx * hd * ELEM * n_attn
        flops = 2.0 * t * ctx * cfg.n_heads * (
            cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim if cfg.mla
            else 2 * cfg.resolved_head_dim) * n_attn
        out.append(OpClass(
            "decode-attention (KV stream)", flops, cache_bytes, t * d * ELEM,
            Interaction.LOCALIZED, True, False,
            "kernels/decode_attn: split-KV online-softmax, VMEM staging"))
    if n_attn and cfg.attn.value != "none" and shape.kind != "decode":
        flops = 4.0 * t * (ctx / 2) * cfg.n_heads * cfg.resolved_head_dim \
            * n_attn * (3 if shape.kind == "train" else 1)
        out.append(OpClass(
            "attention scores/AV", flops, t * d * ELEM * n_attn * 2,
            flops / 100, Interaction.LOCALIZED, True, False,
            "blockwise attention (flash scan) — compute-bound on MXU"))
    if n_dense:
        mult = 3 if cfg.gated_mlp else 2
        flops = (6.0 if shape.kind == "train" else 2.0) \
            * t * mult * d * cfg.d_ff * n_dense
        w_bytes = mult * d * cfg.d_ff * n_dense * ELEM
        act_bytes = t * (d + cfg.d_ff) * n_dense * ELEM
        out.append(OpClass(
            "dense FFN", flops,
            w_bytes if shape.kind == "decode" else w_bytes + act_bytes,
            t * d * ELEM * n_dense, Interaction.INDUCIBLE, True, False,
            "plain MXU GEMM; weight-stationary at decode"))
    if cfg.moe and n_moe:
        m = cfg.moe
        flops = (6.0 if shape.kind == "train" else 2.0) \
            * t * m.top_k * 3 * d * m.d_ff_expert * n_moe
        w_bytes = m.n_experts * 3 * d * m.d_ff_expert * n_moe * ELEM
        out.append(OpClass(
            "MoE expert GEMMs (dynamic-sparse skinny)", flops,
            min(w_bytes, flops / (2 * 128)),
            t * d * ELEM, Interaction.INDUCIBLE, True, True,
            "kernels/moe_group_gemm: empty-tile skip via prefetched counts "
            "(= §5.1.2 command skipping)"))
    if cfg.ssm and n_ssm:
        s = cfg.ssm
        d_inner = s.expand * d
        flops = (6.0 if shape.kind == "train" else 2.0) \
            * t * (2 * d * d_inner + d_inner * s.d_state * 2) * n_ssm
        state_bytes = shape.global_batch * (d_inner * s.d_state) * 4 * n_ssm
        out.append(OpClass(
            "SSD scan (state update)", flops,
            state_bytes if shape.kind == "decode"
            else t * d_inner * ELEM * n_ssm * 3,
            t * d * ELEM, Interaction.SINGLE_OPERAND, True, False,
            "chunked SSD (matmul form); decode = in-place state RMW "
            "(the push-primitive pattern)"))
    # embedding / LM head
    head_flops = 2.0 * t * d * cfg.vocab * (3 if shape.kind == "train" else 1)
    out.append(OpClass(
        "LM head / embedding", head_flops,
        (cfg.vocab * d * ELEM if shape.kind == "decode"
         else t * d * ELEM + cfg.vocab * d * ELEM),
        t * d * ELEM, Interaction.REDUCTION, True, False,
        "chunked-vocab loss (logits never materialize); vocab-sharded"))
    return out


@dataclasses.dataclass(frozen=True)
class PlanEntry:
    op: OpClass
    report: AmenabilityReport
    op_byte: float
    est_pim_speedup: float


def plan(cfg: ArchConfig, shape: ShapeConfig) -> list[PlanEntry]:
    entries = []
    for op in decompose(cfg, shape):
        profile = PrimitiveProfile(
            name=op.name, ops=op.ops, mem_bytes=op.mem_bytes,
            onchip_bytes=max(1.0, op.onchip_bytes),
            interaction=op.interaction, alignable=op.alignable,
            input_dependent_locality=op.input_dependent)
        report = run_test(profile, DEFAULT_PIM, DEFAULT_GPU)
        ob = profile.op_byte
        # §4.3-style estimate: bandwidth-bound ops gain PIM_BW/GPU_BW,
        # derated by how far above pure-streaming the op/byte sits.
        if report.verdict is Verdict.NOT_AMENABLE:
            est = 1.0
        else:
            bw_gain = DEFAULT_PIM.pim_peak_gbps / DEFAULT_GPU.effective_gbps
            ridge = DEFAULT_TPU.ridge_op_byte
            est = max(1.0, bw_gain * min(1.0, ridge / max(ob, 1e-9)) ** 0.5)
        entries.append(PlanEntry(op=op, report=report, op_byte=ob,
                                 est_pim_speedup=est))
    return entries


def render(cfg: ArchConfig, shape: ShapeConfig) -> str:
    rows = [f"PIM offload plan — {cfg.name} x {shape.name}",
            f"{'op':44s} {'op/byte':>8s} {'verdict':>12s} {'est-PIM':>8s}"]
    for e in plan(cfg, shape):
        rows.append(f"{e.op.name[:44]:44s} {e.op_byte:8.2f} "
                    f"{e.report.verdict.value:>12s} "
                    f"{e.est_pim_speedup:7.2f}x")
        rows.append(f"    -> TPU action: {e.op.tpu_action}")
    return "\n".join(rows)
