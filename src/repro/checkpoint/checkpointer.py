"""Atomic, async, resumable checkpointing (numpy container format).

Fault-tolerance contract:

* **Atomicity**: a checkpoint directory becomes visible only via a final
  atomic rename; a crash mid-write never corrupts the latest checkpoint.
* **Async**: ``save_async`` snapshots device arrays to host, then writes on
  a background thread — the train loop stalls only for the device->host
  copy (and at most one outstanding save).
* **Self-describing**: the tree structure is stored as a flattened
  key->array npz plus a JSON manifest (step, config digest, data-pipeline
  state), so restore works across process boundaries and re-sharding
  (arrays are saved unsharded-logical; the restore path applies whatever
  shardings the new mesh wants).
* **Retention**: ``keep`` newest checkpoints survive garbage collection.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template: Any, flat: dict[str, np.ndarray]) -> Any:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: ckpt shape {arr.shape} != "
                             f"expected {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str | pathlib.Path, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> pathlib.Path:
        return self.dir / f"step_{step:010d}"

    def save(self, step: int, state: Any, extra: dict | None = None) -> None:
        flat = _flatten(state)            # device->host snapshot
        self._write(step, flat, extra or {})

    def save_async(self, step: int, state: Any,
                   extra: dict | None = None) -> None:
        self.wait()                        # one outstanding save max
        flat = _flatten(state)             # snapshot NOW (sync copy)
        self._thread = threading.Thread(
            target=self._write, args=(step, flat, extra or {}), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: dict, extra: dict) -> None:
        final = self._step_dir(step)
        tmp = final.with_name(final.name + ".tmp")
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **flat)
        manifest = {"step": step, "time": time.time(),
                    "n_arrays": len(flat), "extra": extra}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: int | None = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into ``template``'s structure; optionally placing leaves
        with ``shardings`` (elastic restore onto a new mesh)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError("no checkpoints")
        d = self._step_dir(step)
        with np.load(d / "arrays.npz") as z:
            flat = {k: z[k] for k in z.files}
        state = _unflatten_into(template, flat)
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        manifest = json.loads((d / "manifest.json").read_text())
        return state, manifest
