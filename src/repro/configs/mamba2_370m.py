"""Mamba2-370m [arXiv:2405.21060; hf:state-spaces/mamba2-370m].

Attention-free SSD stack: 48 Mamba-2 blocks, d_state=128, expand=2,
head_dim=64.  Sub-quadratic: runs the long_500k decode cell.
"""
from .base import ArchConfig, AttnKind, BlockKind, Segment, SsmConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    n_layers=48, d_model=1024, n_heads=16, kv_heads=16,   # unused (attn-free)
    d_ff=0, vocab=50_280,
    attn=AttnKind.NONE,
    segments=(Segment(BlockKind.SSM, 48),),
    ssm=SsmConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=256),
    tied_embeddings=True,
    sub_quadratic=True,
    notes="paper technique's attention-side optimizations inapplicable "
          "(attention-free); SSD scan is the memory-bound primitive",
)
