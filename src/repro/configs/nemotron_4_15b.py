"""Nemotron-4-15B [arXiv:2402.16819].

Dense decoder, GQA (kv=8), squared-ReLU non-gated MLP, huge 256k vocab
(the LM-head/embedding all-gather protagonist of the collective roofline).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    n_layers=32, d_model=6144, n_heads=48, kv_heads=8,
    d_ff=24576, vocab=256_000,
    activation="sq_relu", gated_mlp=False,
    tied_embeddings=False, rope_theta=10_000.0,
    notes="squared-ReLU activation sparsity noted for the PIM planner",
)
