"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B].

Qwen1.5 architecture: full MHA (kv=32 == heads), QKV bias, gated SiLU.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    n_layers=32, d_model=4096, n_heads=32, kv_heads=32,
    d_ff=13440, vocab=92_416,
    activation="silu", gated_mlp=True, qkv_bias=True,
    tied_embeddings=False, rope_theta=1_000_000.0,
)
