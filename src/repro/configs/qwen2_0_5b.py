"""Qwen2-0.5B [arXiv:2407.10671; hf:Qwen/Qwen2-0.5B].

Dense decoder, GQA (kv=2), QKV bias, gated SiLU, tied embeddings.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b",
    n_layers=24, d_model=896, n_heads=14, kv_heads=2,
    d_ff=4864, vocab=151_936,
    activation="silu", gated_mlp=True, qkv_bias=True,
    tied_embeddings=True, rope_theta=1_000_000.0,
)
