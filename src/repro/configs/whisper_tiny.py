"""Whisper-tiny [arXiv:2212.04356].

Encoder-decoder, 4+4 layers, d=384, 6 heads, LayerNorm + GELU (non-gated).
Conv frontend is a STUB: the encoder consumes precomputed frame embeddings
(1500 frames = 30 s at 50 Hz after the stride-2 conv stem).
Deviation noted in DESIGN.md: decoder uses RoPE instead of learned
absolute positions (structure-preserving on TPU).
"""
from .base import ArchConfig, Frontend

CONFIG = ArchConfig(
    name="whisper-tiny",
    n_layers=4, d_model=384, n_heads=6, kv_heads=6,
    d_ff=1536, vocab=51_865,
    activation="gelu", gated_mlp=False,
    tied_embeddings=True,
    enc_dec=True, n_encoder_layers=4, encoder_seq=1500,
    frontend=Frontend.AUDIO_STUB,
)
