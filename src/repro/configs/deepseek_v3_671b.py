"""DeepSeek-V3-671B [arXiv:2412.19437; hf:deepseek-ai/DeepSeek-V3].

MLA (q_lora 1536 / kv_lora 512 / nope 128 / rope 64 / v 128), 61 layers:
3 dense prefix (d_ff 18432) + 58 MoE (1 shared + 256 routed, top-8,
expert d_ff 2048), MTP auxiliary head, vocab 129280.

This is the cell most representative of the paper's technique: MoE
dispatch *is* a dynamically-sparse skinny GEMM (ss-gemm), and MLA decode is
the compressed-KV memory-bound regime.
"""
from .base import ArchConfig, AttnKind, BlockKind, MlaConfig, MoeConfig, Segment

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    n_layers=61, d_model=7168, n_heads=128, kv_heads=128,
    d_ff=18432, vocab=129_280,
    attn=AttnKind.MLA,
    mla=MlaConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    segments=(Segment(BlockKind.DENSE, 3), Segment(BlockKind.MOE, 58)),
    moe=MoeConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1, d_ff_shared=2048,
                  capacity_factor=1.25),
    mtp=True,
    tied_embeddings=False, rope_theta=10_000.0,
)
