"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B] ("moonshot-v1-16b-a3b").

DeepSeek-MoE-style: 48 layers (1 dense prefix + 47 MoE), 64 routed experts
top-6 + 2 shared, expert d_ff 1408, GQA kv=16, vocab 163840.
"""
from .base import ArchConfig, BlockKind, MoeConfig, Segment

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48, d_model=2048, n_heads=16, kv_heads=16,
    d_ff=11264, vocab=163_840,
    segments=(Segment(BlockKind.DENSE, 1), Segment(BlockKind.MOE, 47)),
    moe=MoeConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                  n_shared_experts=2, d_ff_shared=2816,
                  capacity_factor=1.25),
    tied_embeddings=False, rope_theta=50_000.0,
)
