"""Zamba2-1.2B [arXiv:2411.15242; hf:Zyphra/Zamba2-1.2B].

Hybrid: Mamba-2 backbone (d_state=64) with a *shared* transformer block
(GQA kv=32, d_ff=8192) re-applied every ~6 layers (weights shared across
occurrences, as in the paper).  Sub-quadratic: runs long_500k.
Simplification noted in DESIGN.md: one shared block (Zamba2 alternates two)
and no LoRA projectors on the shared block.
"""
from .base import ArchConfig, BlockKind, Segment, SsmConfig

_PATTERN = (
    Segment(BlockKind.SSM, 6), Segment(BlockKind.SHARED_ATTN, 1),
    Segment(BlockKind.SSM, 6), Segment(BlockKind.SHARED_ATTN, 1),
    Segment(BlockKind.SSM, 6), Segment(BlockKind.SHARED_ATTN, 1),
    Segment(BlockKind.SSM, 6), Segment(BlockKind.SHARED_ATTN, 1),
    Segment(BlockKind.SSM, 6), Segment(BlockKind.SHARED_ATTN, 1),
    Segment(BlockKind.SSM, 3),
)

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    n_layers=38, d_model=2048, n_heads=32, kv_heads=32,
    d_ff=8192, vocab=32_000,
    segments=_PATTERN,
    ssm=SsmConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=256),
    shared_attn_every=6,
    tied_embeddings=True,
    sub_quadratic=True,
)
