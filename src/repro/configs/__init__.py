"""Assigned-architecture registry: ``get_config("<arch-id>")``."""
from __future__ import annotations

from .base import ArchConfig, ShapeConfig, SHAPES, shapes_for  # noqa: F401

from . import (codeqwen1_5_7b, deepseek_v3_671b, internvl2_26b, mamba2_370m,
               moonshot_v1_16b_a3b, nemotron_4_15b, qwen2_0_5b,
               starcoder2_3b, whisper_tiny, zamba2_1_2b)

REGISTRY: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (starcoder2_3b, nemotron_4_15b, qwen2_0_5b, codeqwen1_5_7b,
              mamba2_370m, internvl2_26b, whisper_tiny, zamba2_1_2b,
              deepseek_v3_671b, moonshot_v1_16b_a3b)
}


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


ALL_ARCHS = tuple(sorted(REGISTRY))
