"""StarCoder2-3B [arXiv:2402.19173; hf:bigcode/starcoder2-3b].

Dense decoder, GQA (kv=2), RoPE, non-gated GELU MLP, tied embeddings.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b",
    n_layers=30, d_model=3072, n_heads=24, kv_heads=2,
    d_ff=12288, vocab=49152,
    activation="gelu", gated_mlp=False, qkv_bias=True,
    tied_embeddings=True, rope_theta=100_000.0,
    notes="GQA kv=2; bias on projections per hf config",
)
