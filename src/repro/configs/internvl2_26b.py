"""InternVL2-26B [arXiv:2404.16821; hf:OpenGVLab/InternVL2-26B].

InternViT-6B vision frontend (STUB: precomputed patch embeddings per the
assignment) + InternLM2-20B language backbone: 48L, GQA kv=8, gated SiLU.
"""
from .base import ArchConfig, Frontend

CONFIG = ArchConfig(
    name="internvl2-26b",
    n_layers=48, d_model=6144, n_heads=48, kv_heads=8,
    d_ff=16384, vocab=92_553,
    activation="silu", gated_mlp=True,
    tied_embeddings=False, rope_theta=1_000_000.0,
    frontend=Frontend.VISION_STUB, vision_tokens=256,
    notes="vision tokens = 256 precomputed patch embeddings (one 448px "
          "tile after pixel-shuffle); backbone only per assignment",
)
