"""Architecture + shape configuration schema.

One ``ArchConfig`` per assigned architecture (exact public-literature
numbers live in the per-arch files).  ``reduced()`` produces the same
family at smoke-test scale (tiny widths/depths, same structural features)
for the per-arch CPU tests; full configs are exercised only through the
dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Sequence


class AttnKind(enum.Enum):
    GQA = "gqa"
    MLA = "mla"          # deepseek-v3 multi-head latent attention
    NONE = "none"        # attention-free (pure SSM)


class BlockKind(enum.Enum):
    DENSE = "dense"          # attn + dense FFN
    MOE = "moe"              # attn + routed-experts FFN
    SSM = "ssm"              # mamba2 SSD block
    SHARED_ATTN = "shared"   # zamba2-style shared transformer block


class Frontend(enum.Enum):
    NONE = "none"
    VISION_STUB = "vision"   # precomputed patch embeddings (VLM)
    AUDIO_STUB = "audio"     # precomputed frame embeddings (enc-dec audio)


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    d_ff_shared: int = 0               # shared-expert hidden size
    capacity_factor: float = 1.25
    router_noise: float = 0.0


@dataclasses.dataclass(frozen=True)
class MlaConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SsmConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class Segment:
    """A run of identical layers, scanned as one unit."""

    kind: BlockKind
    count: int


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    # structure
    segments: tuple[Segment, ...] = ()
    attn: AttnKind = AttnKind.GQA
    activation: str = "silu"           # silu|gelu|sq_relu
    gated_mlp: bool = True
    qkv_bias: bool = False
    tied_embeddings: bool = False
    head_dim: int | None = None
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # family extensions
    moe: MoeConfig | None = None
    mla: MlaConfig | None = None
    ssm: SsmConfig | None = None
    shared_attn_every: int = 0         # zamba2: shared block period
    mtp: bool = False                  # deepseek multi-token prediction
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500            # audio frames after the conv stub
    frontend: Frontend = Frontend.NONE
    vision_tokens: int = 0             # VLM stub: prefix length
    sub_quadratic: bool = False        # eligible for long_500k
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def resolved_segments(self) -> tuple[Segment, ...]:
        if self.segments:
            return self.segments
        return (Segment(BlockKind.DENSE, self.n_layers),)

    def reduced(self) -> "ArchConfig":
        """Smoke-test-scale config of the same family."""
        segs = tuple(Segment(s.kind, min(s.count, 2))
                     for s in self.resolved_segments()[:4])
        moe = None
        if self.moe:
            # capacity_factor high enough that nothing drops at smoke scale,
            # so cached decode exactly matches the full forward.
            moe = dataclasses.replace(
                self.moe, n_experts=min(8, self.moe.n_experts),
                top_k=min(2, self.moe.top_k), d_ff_expert=64,
                d_ff_shared=64 if self.moe.n_shared_experts else 0,
                capacity_factor=8.0)
        mla = MlaConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=8,
                        qk_rope_head_dim=8, v_head_dim=8) if self.mla else None
        ssm = dataclasses.replace(self.ssm, d_state=16, head_dim=8,
                                  chunk=8) if self.ssm else None
        return dataclasses.replace(
            self, name=self.name + "-smoke",
            n_layers=sum(s.count for s in segs), d_model=64,
            n_heads=4, kv_heads=min(4, max(1, self.kv_heads * 4
                                           // max(1, self.n_heads))),
            d_ff=128, vocab=256, head_dim=16, segments=segs, moe=moe,
            mla=mla, ssm=ssm,
            shared_attn_every=2 if self.shared_attn_every else 0,
            n_encoder_layers=min(2, self.n_encoder_layers),
            encoder_seq=16 if self.enc_dec else self.encoder_seq,
            vision_tokens=8 if self.vision_tokens else 0)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shapes_for(arch: ArchConfig) -> Sequence[ShapeConfig]:
    """The assignment's shape set for an arch (long_500k only for
    sub-quadratic families; all archs here have decoders)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if arch.sub_quadratic:
        names.append("long_500k")
    return [SHAPES[n] for n in names]
